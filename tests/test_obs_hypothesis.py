"""Property tests for the obs histogram laws (optional dep: hypothesis).

Deterministic counterparts of both properties live in tests/test_obs.py so
the laws stay covered when hypothesis isn't installed (the baked CI image
doesn't ship it; ``pip install '.[test]'`` to run these).

Law 1 — merge identity: quantiles are a pure function of
(boundaries, counts, min, max), so merging per-host histograms yields
IDENTICAL quantiles to a single histogram fed the concatenated samples.
This is what makes the fleet straggler report's cross-host percentiles
exact rather than approximate.

Law 2 — bounded interpolation error: against numpy's ``method="lower"``
order statistic (the one the bucket counts actually locate), the
interpolated quantile is within one bucket width. The bound does NOT hold
against numpy's default linear interpolation on sparse data: e.g. samples
``[0, 0, 0, 10]`` at q=0.75 — linear interpolation jumps across the whole
empty gap between clusters while every order statistic sits on a sample.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install '.[test]' to run these")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.obs.metrics import Histogram, exponential_boundaries  # noqa: E402

BOUNDS = exponential_boundaries(1e-3, 1e3, 60)

samples_strategy = st.lists(
    st.floats(min_value=1e-4, max_value=1e4,
              allow_nan=False, allow_infinity=False),
    min_size=1, max_size=300)


@settings(max_examples=200, deadline=None)
@given(samples=samples_strategy,
       n_hosts=st.integers(min_value=1, max_value=5),
       q=st.floats(min_value=0.0, max_value=1.0))
def test_merged_histograms_equal_concatenated(samples, n_hosts, q):
    single = Histogram("all", boundaries=BOUNDS)
    for v in samples:
        single.record(v)

    merged = Histogram("merged", boundaries=BOUNDS)
    for part in np.array_split(np.asarray(samples), n_hosts):
        h = Histogram("host", boundaries=BOUNDS)
        for v in part:
            h.record(float(v))
        merged.merge(h)

    assert merged.count == single.count
    assert merged.quantile(q) == single.quantile(q)  # exact equality
    assert merged.percentiles() == single.percentiles()


@settings(max_examples=200, deadline=None)
@given(samples=st.lists(
    st.floats(min_value=0.0, max_value=10.0,
              allow_nan=False, allow_infinity=False),
    min_size=1, max_size=500),
    q=st.sampled_from([0.5, 0.9, 0.99]))
def test_quantile_within_one_bucket_width_of_numpy(samples, q):
    bounds = list(np.linspace(0.0, 10.0, 101))
    width = bounds[1] - bounds[0]
    h = Histogram("u", boundaries=bounds)
    for v in samples:
        h.record(v)
    exact = float(np.quantile(np.asarray(samples), q, method="lower"))
    assert abs(h.quantile(q) - exact) <= width + 1e-9
