"""End-to-end driver: GRPO post-training of a ~100M-parameter model for a few
hundred steps on the synthetic math task (deliverable b's end-to-end arm).

By default runs a ~100M llama-style model for --iters steps. On a CPU host
this is slow (~100M params x rollout+train per iteration); pass --small for
a ~20M config that finishes a few hundred steps in reasonable time, or
--iters 5 for a smoke pass. On TPU the same script runs the full config
unchanged.

    PYTHONPATH=src python examples/train_grpo_100m.py --small --iters 200
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

from repro.api import ExperimentSpec
from repro.configs import ARCHS
from repro.ft import checkpoint
from repro.rl import RLConfig


def model_100m():
    """~100M params: 12L, d=768, llama-style, byte vocab."""
    return dataclasses.replace(
        ARCHS["qwen2.5-7b"],
        name="qwen-mini-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=260,
        pad_heads_to=1, rope_theta=10_000.0,
    )


def model_20m():
    return dataclasses.replace(
        model_100m(), name="qwen-mini-20m", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, d_ff=1024,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--small", action="store_true", help="~20M instead of 100M")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_20m() if args.small else model_100m()
    n_params = cfg.num_params()
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")
    rl = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=4,
                  lr=1e-4, kl_coef=0.001)
    exp = ExperimentSpec(model=cfg, rl=rl, prompts_per_iter=8, seed=0)
    pipe = exp.compile()

    t0 = time.perf_counter()
    for it in range(args.iters):
        m = pipe.worker.run_iteration()
        if it % 10 == 0 or it == args.iters - 1:
            dt = time.perf_counter() - t0
            print(f"it={it:03d} ({dt:.0f}s) reward={m['reward/mean']:.3f} "
                  f"entropy={m['actor/entropy']:.3f} "
                  f"clipfrac={m['actor/clipfrac']:.3f}", flush=True)
        if args.ckpt_dir and (it + 1) % 100 == 0:
            checkpoint.save(args.ckpt_dir, pipe.ctx.actor_state, step=it + 1)


if __name__ == "__main__":
    main()
