"""Quickstart: GRPO post-training of a tiny LM on synthetic math, through the
full DistFlow pipeline (DAG planner -> DAG worker -> data coordinator).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import ExperimentSpec
from repro.configs import ARCHS, DataCoordinatorConfig, reduced
from repro.rl import RLConfig


def main():
    # a reduced gemma-family config (CPU-sized)
    cfg = reduced(ARCHS["gemma-2b"], vocab_size=260, num_layers=2,
                  d_model=128, d_ff=256)
    # the whole run is one declarative spec: swap algorithm="grpo" for
    # "ppo", "rloo", or "reinforce_pp" and everything downstream follows.
    # Data Coordinator v2 flags (double buffer + prefetch) are bitwise-
    # identical to the synchronous path.
    exp = ExperimentSpec(
        model=cfg,
        rl=RLConfig(algorithm="grpo", group_size=8, max_new_tokens=4,
                    lr=3e-4, kl_coef=0.0),
        coordinator=DataCoordinatorConfig(double_buffer=True, prefetch=1),
        prompts_per_iter=8,
        seed=0,
    )
    pipe = exp.compile()

    print("execution plan (paper Fig. 4 serialization):", pipe.plan.order)
    for it in range(20):
        m = pipe.worker.run_iteration()
        print(f"it={it:02d} reward={m['reward/mean']:.3f} "
              f"entropy={m['actor/entropy']:.3f} kl={m['actor/kl']:.4f}")
    print("databuffer stats:", pipe.buffer.stats)


if __name__ == "__main__":
    main()
