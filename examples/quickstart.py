"""Quickstart: GRPO post-training of a tiny LM on synthetic math, through the
full DistFlow pipeline (DAG planner -> DAG worker -> data coordinator).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import ARCHS, DataCoordinatorConfig, reduced
from repro.core import build_pipeline
from repro.rl import RLConfig


def main():
    # a reduced gemma-family config (CPU-sized)
    cfg = reduced(ARCHS["gemma-2b"], vocab_size=260, num_layers=2,
                  d_model=128, d_ff=256)
    rl = RLConfig(algorithm="grpo", group_size=8, max_new_tokens=4,
                  lr=3e-4, kl_coef=0.0)
    # Data Coordinator v2: double-buffered stage handoffs + dataloader
    # prefetch (values are bitwise-identical to the synchronous path)
    coord = DataCoordinatorConfig(double_buffer=True, prefetch=1)
    pipe = build_pipeline(cfg, rl, prompts_per_iter=8, seed=0,
                          coordinator=coord)

    print("execution plan (paper Fig. 4 serialization):", pipe.plan.order)
    for it in range(20):
        m = pipe.worker.run_iteration()
        print(f"it={it:02d} reward={m['reward/mean']:.3f} "
              f"entropy={m['actor/entropy']:.3f} kl={m['actor/kl']:.4f}")
    print("databuffer stats:", pipe.buffer.stats)


if __name__ == "__main__":
    main()
