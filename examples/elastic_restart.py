"""Fault-tolerance demo: checkpoint -> simulated failure -> ELASTIC restart.

Trains a few iterations, checkpoints the sharded actor state, "loses" the
job, then resumes in a fresh pipeline — and verifies the restored params are
bitwise identical and training continues. The same checkpoint restores onto
a different mesh topology (see tests/test_multidevice.py for the 8-device
(4,2)->(2,2,2) elastic proof).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import build_pipeline
from repro.ft import checkpoint
from repro.rl import RLConfig


def main():
    cfg = reduced(ARCHS["qwen2.5-7b"], vocab_size=260, num_layers=2)
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=4, lr=3e-4)
    ckpt_dir = tempfile.mkdtemp(prefix="distflow_ckpt_")
    try:
        pipe = build_pipeline(cfg, rl, prompts_per_iter=4, seed=7)
        for it in range(3):
            m = pipe.worker.run_iteration()
            print(f"[run-1] it={it} reward={m['reward/mean']:.3f}")
        checkpoint.save(ckpt_dir, pipe.ctx.actor_state, step=3)
        want = jax.tree.leaves(pipe.ctx.actor_state.params)[0]
        print(f"[run-1] checkpointed at step 3 -> {ckpt_dir}")
        del pipe  # --- simulated node failure: the whole job dies ---

        pipe2 = build_pipeline(cfg, rl, prompts_per_iter=4, seed=7)
        restored, step = checkpoint.restore(ckpt_dir, pipe2.ctx.actor_state)
        pipe2.ctx.actor_state = restored
        got = jax.tree.leaves(restored.params)[0]
        assert np.array_equal(np.asarray(want), np.asarray(got)), "params differ!"
        print(f"[run-2] restored step={step}; params bitwise identical")
        for it in range(step, step + 3):
            m = pipe2.worker.run_iteration()
            print(f"[run-2] it={it} reward={m['reward/mean']:.3f}")
        print("[run-2] resumed training OK")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
