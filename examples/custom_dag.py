"""Custom-DAG example (paper §4-§5): extend the pipeline WITHOUT touching the
framework.

Two customizations in ~30 lines:
 1. a new node function — a length-penalty reward registered under
    (REWARD, MODEL_INFERENCE) — mapped into the graph next to the built-in
    function reward;
 2. a restructured DAG — GRPO *without* a reference model (no KL term), the
    common cost-saving variant.

The planner serializes the two same-depth reward nodes automatically
(Fig. 4), and the databuffer carries the extra field with no framework edits.

    PYTHONPATH=src python examples/custom_dag.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.api import ExperimentSpec
from repro.configs import ARCHS, reduced
from repro.core import DAG, Node, NodeType, Role
from repro.core.registry import default_registry
from repro.rl import RLConfig


# ---- 1. a brand-new stage function ---------------------------------------- #
def length_penalty_reward(ctx, buffer, node):
    """Blend the math reward with a brevity bonus: shaped = r + 0.05 * (1 - len/max)."""
    spec = P(tuple(ctx.mesh.axis_names))
    mask = buffer.get("response_mask", spec)
    r = buffer.get("rewards", P(spec[0]))
    lengths = jnp.sum(mask.astype(jnp.float32), axis=1)
    shaped = r + 0.05 * (1.0 - lengths / ctx.rl.max_new_tokens)
    buffer.put("rewards", shaped, P(spec[0]))
    return {"reward/shaped_mean": float(jnp.mean(shaped))}


# ---- 2. a restructured DAG: GRPO without the reference model --------------- #
def grpo_no_ref_dag() -> DAG:
    return DAG.from_nodes([
        Node("actor_generation", Role.ACTOR, NodeType.GENERATE),
        Node("reward_compute", Role.REWARD, NodeType.COMPUTE,
             deps=("actor_generation",)),
        Node("length_penalty", Role.REWARD, NodeType.MODEL_INFERENCE,
             deps=("reward_compute",)),
        Node("advantage_compute", Role.ADVANTAGE, NodeType.COMPUTE,
             deps=("length_penalty",)),
        Node("actor_train", Role.ACTOR, NodeType.MODEL_TRAIN,
             deps=("advantage_compute",)),
    ])


def main():
    cfg = reduced(ARCHS["mixtral-8x7b"], vocab_size=260, num_layers=2)
    # kl_coef=0 -> the loss never reads ref_logprob, so dropping the node is safe
    rl = RLConfig(algorithm="grpo", group_size=4, max_new_tokens=6,
                  lr=3e-4, kl_coef=0.0)

    # a registry that knows the new node (Fig. 5 extension point)
    reg = default_registry()
    reg.register(Role.REWARD, NodeType.MODEL_INFERENCE, length_penalty_reward,
                 override=True)
    # the whole experiment — model, rl, custom DAG — is one declarative,
    # JSON-serializable spec; only the registry (live functions) rides along
    # as a compile() argument
    exp = ExperimentSpec(model=cfg, rl=rl, prompts_per_iter=4,
                         dag=grpo_no_ref_dag().to_spec())
    pipe = exp.compile(registry=reg)

    print("custom plan:", pipe.plan.order)
    assert "reference_inference" not in pipe.plan.order
    for it in range(5):
        m = pipe.worker.run_iteration()
        print(f"it={it} reward={m['reward/mean']:.3f} "
              f"shaped={m['reward/shaped_mean']:.3f}")


if __name__ == "__main__":
    main()
