#!/usr/bin/env bash
# Tier-1 verify: the full pytest suite sharded into parallel file chunks,
# plus the docs checker (scripts/check_docs.py) as its own chunk.
#
# The suite is ~18 min serially; CI runners cap a single command at ~10 min.
# This script splits the test files into chunks balanced by observed runtime
# (each chunk comfortably under the 10-min budget) and runs them as parallel
# pytest processes. Any test file not named in a chunk is auto-appended to
# the last chunk, so new test files are never silently skipped.
#
# Every chunk reports its wall time ("ok in 412s") so drift toward the
# 10-min cap is visible before it breaks CI, and the script exits non-zero
# if ANY chunk fails (verified by tests/test_tooling.py with an
# intentionally failing chunk).
#
#   bash scripts/ci.sh            # run everything, exit non-zero on failure
#   CI_CHUNKS='tests/a.py;tests/b.py tests/c.py' bash scripts/ci.sh
#                                 # override the chunk list (';'-separated
#                                 # chunks of pytest args) — used by the
#                                 # tooling tests; disables auto-append and
#                                 # the docs chunk
#
# This is the documented verify command (see [tool.distflow] in
# pyproject.toml).
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ -n "${CI_CHUNKS:-}" ]]; then
  IFS=';' read -r -a CHUNKS <<<"$CI_CHUNKS"
  run_docs=0
  run_fleet=0
else
  # Chunks balanced by runtime: the learning/convergence tests, the
  # subprocess-heavy multidevice file, and the kernel sweeps dominate.
  # test_rollout_engine (~1 min of engine compiles) rides with chunk 2,
  # the lightest chunk in the last measured layout (603s vs 987s for the
  # heaviest under 5-way parallel contention). test_envs (~2 min fast tests
  # + ~100s calculator-GRPO learning run) rides with chunk 4, the second-
  # lightest in that layout. test_serving (~35s of serving-engine compiles)
  # rides with chunk 2 as well — still well under the heaviest chunk.
  CHUNKS=(
    "tests/test_pipeline.py tests/test_rl.py tests/test_extensions.py"
    "tests/test_multidevice.py tests/test_core.py tests/test_ft.py tests/test_coordinator.py tests/test_rollout_engine.py tests/test_serving.py"
    "tests/test_kernels.py tests/test_kernels_hypothesis.py tests/test_property.py tests/test_models_units.py tests/test_async_pipeline.py tests/test_tooling.py tests/test_obs.py tests/test_obs_hypothesis.py"
    "tests/test_algorithms.py tests/test_benchmarks.py tests/test_sharding.py tests/test_arch_smoke.py tests/test_workloads.py tests/test_envs.py"
  )
  run_docs=1
  # The simulated-fleet suite (docs/multihost.md) gets its own chunk: it
  # spawns multi-process fleets whose subprocesses each force their own
  # XLA device count, so it must not share a pytest process with files
  # that shape the parent's jax state. ~7 min under contention — near the
  # cap, so it rides alone with its own XLA_FLAGS for the in-process
  # device probes.
  FLEET_CHUNK="tests/test_fleet.py"
  FLEET_XLA_FLAGS="--xla_force_host_platform_device_count=8"
  run_fleet=1

  # append any unlisted test file to the last chunk
  listed=" ${CHUNKS[*]} $FLEET_CHUNK "
  extra=""
  for f in tests/test_*.py; do
    [[ "$listed" == *" $f "* ]] || extra="$extra $f"
  done
  if [[ -n "$extra" ]]; then
    echo "[ci] unlisted test files appended to final chunk:$extra"
    CHUNKS[$((${#CHUNKS[@]} - 1))]+="$extra"
  fi
fi

logdir="$(mktemp -d "${TMPDIR:-/tmp}/ci-logs.XXXXXX")"
njobs=$((${#CHUNKS[@]} + run_docs + run_fleet))
echo "[ci] $njobs parallel chunks; logs in $logdir"

# Each chunk runs in a background subshell that records its own wall time;
# the subshell's exit code is the underlying command's, so `wait` propagates
# any failure to the final exit status.
pids=()
names=()
i=0
for chunk in "${CHUNKS[@]}"; do
  i=$((i + 1))
  (
    t0=$(date +%s)
    python -m pytest -q $chunk >"$logdir/chunk$i.log" 2>&1
    rc=$?
    echo $(($(date +%s) - t0)) >"$logdir/chunk$i.time"
    exit $rc
  ) &
  pids+=($!)
  names+=("chunk$i")
done
if [[ $run_fleet -eq 1 ]]; then
  (
    t0=$(date +%s)
    XLA_FLAGS="$FLEET_XLA_FLAGS" JAX_PLATFORMS=cpu \
      python -m pytest -q $FLEET_CHUNK >"$logdir/fleet.log" 2>&1
    rc=$?
    echo $(($(date +%s) - t0)) >"$logdir/fleet.time"
    exit $rc
  ) &
  pids+=($!)
  names+=("fleet")
fi
if [[ $run_docs -eq 1 ]]; then
  (
    t0=$(date +%s)
    python scripts/check_docs.py >"$logdir/docs.log" 2>&1
    rc=$?
    echo $(($(date +%s) - t0)) >"$logdir/docs.time"
    exit $rc
  ) &
  pids+=($!)
  names+=("docs")
fi

status=0
for idx in "${!pids[@]}"; do
  n="${names[$idx]}"
  log="$logdir/$n.log"
  if wait "${pids[$idx]}"; then
    echo "[ci] $n ok in $(cat "$logdir/$n.time")s: $(tail -n 1 "$log")"
  else
    status=1
    echo "[ci] $n FAILED in $(cat "$logdir/$n.time")s: $(tail -n 1 "$log")"
    echo "----- last 40 lines of $log -----"
    tail -n 40 "$log"
  fi
done

# Per-chunk wall times through the obs JSONL sink (docs/observability.md):
# CI timing is machine-readable, same record shape the training driver
# emits. CI_OBS_JSONL overrides the default path; failure to write the
# timing file never fails the build.
ci_jsonl="${CI_OBS_JSONL:-$logdir/ci_times.jsonl}"
python - "$ci_jsonl" "$logdir" "${names[@]}" <<'PY' || true
import sys
from repro.obs.sinks import JSONLSink
out, logdir, names = sys.argv[1], sys.argv[2], sys.argv[3:]
with JSONLSink(out) as sink:
    for n in names:
        try:
            with open(f"{logdir}/{n}.time") as f:
                wall = float(f.read().strip())
        except (OSError, ValueError):
            continue
        sink.write({"kind": "ci_chunk", "chunk": n, "wall_s": wall})
print(f"[ci] chunk times -> {out}")
PY
exit $status
