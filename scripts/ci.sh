#!/usr/bin/env bash
# Tier-1 verify: the full pytest suite sharded into parallel file chunks.
#
# The suite is ~18 min serially; CI runners cap a single command at ~10 min.
# This script splits the test files into chunks balanced by observed runtime
# (each chunk comfortably under the 10-min budget) and runs them as parallel
# pytest processes. Any test file not named in a chunk is auto-appended to
# the last chunk, so new test files are never silently skipped.
#
#   bash scripts/ci.sh            # run everything, exit non-zero on failure
#
# This is the documented verify command (see [tool.distflow] in
# pyproject.toml).
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Chunks balanced by runtime: the learning/convergence tests, the
# subprocess-heavy multidevice file, and the kernel sweeps dominate.
CHUNKS=(
  "tests/test_pipeline.py tests/test_rl.py tests/test_extensions.py"
  "tests/test_multidevice.py tests/test_core.py tests/test_ft.py tests/test_coordinator.py"
  "tests/test_kernels.py tests/test_kernels_hypothesis.py tests/test_property.py tests/test_models_units.py"
  "tests/test_algorithms.py tests/test_benchmarks.py tests/test_sharding.py tests/test_arch_smoke.py tests/test_workloads.py"
)

# append any unlisted test file to the last chunk
listed=" ${CHUNKS[*]} "
extra=""
for f in tests/test_*.py; do
  [[ "$listed" == *" $f "* ]] || extra="$extra $f"
done
if [[ -n "$extra" ]]; then
  echo "[ci] unlisted test files appended to final chunk:$extra"
  CHUNKS[$((${#CHUNKS[@]} - 1))]+="$extra"
fi

logdir="$(mktemp -d "${TMPDIR:-/tmp}/ci-logs.XXXXXX")"
echo "[ci] ${#CHUNKS[@]} parallel chunks; logs in $logdir"

pids=()
i=0
for chunk in "${CHUNKS[@]}"; do
  i=$((i + 1))
  (python -m pytest -q $chunk >"$logdir/chunk$i.log" 2>&1) &
  pids+=($!)
done

status=0
for idx in "${!pids[@]}"; do
  n=$((idx + 1))
  log="$logdir/chunk$n.log"
  if wait "${pids[$idx]}"; then
    echo "[ci] chunk$n ok: $(tail -n 1 "$log")"
  else
    status=1
    echo "[ci] chunk$n FAILED: $(tail -n 1 "$log")"
    echo "----- last 40 lines of $log -----"
    tail -n 40 "$log"
  fi
done
exit $status
