#!/usr/bin/env python
"""Execute every ```python fence in the shipped docs against src/.

Documentation code that nobody runs drifts: an API rename that misses a doc
page ships a broken example (the PR-2 migration nearly did exactly this).
This checker extracts every fenced code block whose info string is exactly
``python`` from README.md and docs/*.md and executes it — fences in the same
file share one namespace, top to bottom, so examples may build on earlier
ones exactly as a reader would run them.

Conventions:
  * ```python        — executed (must run cleanly against src/)
  * ```python no-check — rendered as Python by GitHub, never executed
                         (for deliberately illustrative fragments)
  * any other info string (json, bash, mermaid, text, none) — ignored

Usage:
  python scripts/check_docs.py             # README.md + docs/*.md
  python scripts/check_docs.py FILE [...]  # explicit files (tests use this)

Exit status is non-zero if any fence fails; failures print the file, the
fence's line number, and the traceback. Wired into scripts/ci.sh as its own
parallel chunk.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback
from typing import List, Tuple  # noqa: F401 (Tuple used in TIMINGS annot)

REPO = pathlib.Path(__file__).resolve().parent.parent


def extract_fences(text: str) -> List[Tuple[int, str, str]]:
    """(opener_line, info_string, body) for every fenced code block."""
    fences = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped.startswith("```") and stripped != "```":
            info = stripped[3:].strip()
            body, opener = [], i + 1  # 1-indexed line of the ``` opener
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            fences.append((opener, info, "\n".join(body)))
        i += 1
    return fences


TIMINGS: List[Tuple[float, str]] = []  # (seconds, "file:line") per fence


def check_file(path: pathlib.Path) -> List[str]:
    """Run the file's python fences in one shared namespace; return errors."""
    errors = []
    namespace: dict = {"__name__": "__check_docs__"}
    for lineno, info, body in extract_fences(path.read_text()):
        if info != "python":
            continue
        t0 = time.perf_counter()
        try:
            code = compile(body, f"{path}:{lineno}", "exec")
            exec(code, namespace)  # noqa: S102 - executing our own docs
            status = "ok"
        except Exception:
            errors.append(
                f"{path}:{lineno}: fence failed\n{traceback.format_exc()}"
            )
            status = "FAIL"
        elapsed = time.perf_counter() - t0
        rel = path.relative_to(REPO) if path.is_relative_to(REPO) else path
        TIMINGS.append((elapsed, f"{rel}:{lineno}"))
        print(f"[check_docs] {rel}:{lineno} {status} ({elapsed:.1f}s)",
              flush=True)
    return errors


def print_slowest(n: int = 5) -> None:
    """Per-fence execution-time summary: the slowest fences are where the
    docs chunk's CI wall time hides — surface them so a doc edit that drags
    in a heavyweight example is visible before it drifts toward the cap."""
    if not TIMINGS:
        return
    total = sum(t for t, _ in TIMINGS)
    top = sorted(TIMINGS, reverse=True)[:n]
    print(f"[check_docs] {len(TIMINGS)} fences in {total:.1f}s; slowest:")
    for elapsed, where in top:
        print(f"[check_docs]   {elapsed:6.1f}s  {where}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="markdown files to check (default: README.md + docs/*.md)")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO / "src"))
    paths = args.paths or [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

    all_errors = []
    for path in paths:
        all_errors.extend(check_file(path))
    print_slowest()
    if all_errors:
        print("\n".join(all_errors), file=sys.stderr)
        print(f"[check_docs] {len(all_errors)} fence(s) FAILED", flush=True)
        return 1
    print(f"[check_docs] all python fences pass ({len(paths)} files)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
